"""Fault-injected chaos soak -> ``experiments/BENCH_chaos.json``.

The PR-10 acceptance benchmark (DESIGN.md §11): the multi-tenant fleet
replayed under the ragged/churn traffic of ``tests/traffic.py`` while the
fault injector (``repro.serve.faults``) breaks it on purpose, one fault
class per scenario plus a seeded combined soak:

  * **scenario cells, one per fault class** — transient executor
    exceptions (bounded retry + backoff), persistent exceptions (breaker
    trip + graceful backend degrade), hangs and slow-starts (deadline
    supervision abandons the stuck block and recomputes it), device loss
    (re-plan onto survivors / unplaced fallback), and a corrupt deploy
    candidate (rejected by the bit-identity smoke check mid-incident).
    Every cell reports injected/detected counts, wrong answers vs the
    ``predict_codes`` oracle (must be 0), lost accepted requests (must be
    0), and the lane's recovery p99.
  * **degraded-mode throughput floor** — the same trace replayed clean
    vs through a trip-and-degrade incident; the degraded/clean rows-per-
    second ratio must clear a hard floor.
  * **stream failover, per backend** — a churned stream trace served
    through ``stream/replica.py`` (sync ack replication + periodic
    checkpoints); the primary is killed mid-trace and the standby must
    recover every stream bit-identically from its last checkpoint plus
    the replayed acked tail.  Zero acked steps lost, zero mismatches.
  * **seeded combined soak** — a ``FaultPlan.seeded`` schedule sprinkled
    over a longer trace: the whole supervision stack running at once,
    still zero wrong / zero lost.

Chaos is a *control-flow* benchmark: the nets are always the reduced
configs (the fault seams and the supervision machinery are identical for
the Table-II architectures) and CPU wall numbers are structural.  The
gate in ``check_regression.py --suite chaos`` replays the contract and
compares recovery/throughput cell-by-cell.

    PYTHONPATH=src python -m benchmarks.chaos_soak [--fast] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time

import numpy as np

# tests/traffic.py is the shared trace generator (pure numpy, no package):
# pytest sees it via rootdir, benchmarks via this explicit insert
TESTS = os.path.join(os.path.dirname(__file__), "..", "tests")
if TESTS not in sys.path:
    sys.path.insert(0, TESTS)
import traffic  # noqa: E402

DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "..", "experiments",
                           "BENCH_chaos.json")
SCHEMA_VERSION = 1
# the one definition of "smoke-sized" (CI perf-gate and run.py --fast)
FAST_KW = dict(n_events=12, soak_events=30, stream_events=18, block=16,
               stream_backends=("take",))
# the nightly long soak: an order of magnitude more traffic under the
# same seeded fault density, so slow-leak failure modes (retry storms,
# checkpoint drift, quarantine livelock) have room to show up
LONG_KW = dict(n_events=80, soak_events=400, stream_events=150, block=32)


def write_results(results: dict, out: str = DEFAULT_OUT) -> str:
    out = os.path.abspath(out)
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(results, f, indent=2)
    return out


def _make_nets(tasks, seed: int):
    import jax

    from repro import pipeline
    from repro.configs import paper_tasks
    from repro.core import assemble

    nets = {}
    for i, task in enumerate(tasks):
        cfg = paper_tasks.reduced(task)
        params = assemble.init(jax.random.PRNGKey(seed + i), cfg)
        nets[task] = pipeline.compile_network(params, cfg)
    # warm the lookup paths outside the timed/deadline-supervised replays
    # so first-dispatch jit compiles never masquerade as incidents
    for net in nets.values():
        x = np.zeros((4, net.cfg.in_features), np.float32)
        for be in ("take", "onehot"):
            net.predict_codes(x, backend=be)
    return nets


# ---------------------------------------------------------------------------
# faulted ragged replay (the scenario harness)

def _faulted_replay(nets, inj, policy, *, n_events, block, seed,
                    backends=None, placements=None, at_event=None):
    """Replay one ragged trace through a fresh fleet with the given
    injector; returns ``(fleet, per_tenant_summaries, counters)``.

    ``at_event`` maps an event index to a callable run just before that
    event's submit (deploys racing the incident).  Counters hold the two
    hard contract numbers: ``wrong`` (served codes vs the
    ``predict_codes`` oracle) and ``lost`` (accepted requests that never
    completed)."""
    from repro.serve import LUTFleet
    from repro.serve.registry import make_reference

    fleet = LUTFleet(block=block, faults=inj, policy=policy)
    feats = {}
    for t, net in nets.items():
        fleet.register(t, net, reference=make_reference(net, n=16),
                       backend=(backends or {}).get(t),
                       placement=(placements or {}).get(t))
        feats[t] = net.cfg.in_features
    trace = traffic.ragged_trace(list(nets), n_events=n_events, seed=seed)
    inputs = traffic.make_inputs(trace, feats, seed=seed + 1)
    acked = []
    t0 = time.perf_counter()
    for i, (ev, xs) in enumerate(zip(trace, inputs)):
        if at_event and i in at_event:
            at_event[i](fleet)
        reqs, _ = fleet.submit_many(ev.model_id, xs)
        if reqs:
            acked.append((ev.model_id, reqs))
        fleet.tick()
        for _ in range(ev.gap_ticks):
            fleet.tick()
    fleet.pump()
    elapsed = time.perf_counter() - t0

    wrong = lost = accepted = 0
    for mid, reqs in acked:
        accepted += len(reqs)
        lost += sum(1 for r in reqs if not r.done)
        done = [r for r in reqs if r.done]
        if done:
            ref = np.asarray(nets[mid].predict_codes(
                np.stack([r.x for r in done])))
            got = np.stack([np.asarray(r.codes) for r in done])
            wrong += int((got != ref).any(axis=-1).sum())
    summaries = {t: fleet.summary(t) for t in nets}
    counters = {"accepted": accepted, "wrong": wrong, "lost": lost,
                "elapsed_s": round(elapsed, 4)}
    return fleet, summaries, counters


def _cell(name, kind, inj, summaries, counters, scope, *,
          detected, recovered, extra=None):
    s = summaries[scope]
    out = {
        "name": name, "kind": kind, "scope": scope,
        "injected": inj.fired(kind),
        "detected": int(detected),
        "recovered": bool(recovered),
        "wrong": counters["wrong"], "lost": counters["lost"],
        "accepted": counters["accepted"],
        "completed": sum(x["completed"] for x in summaries.values()),
        "shed": sum(x["shed"] for x in summaries.values()),
        "deferred": sum(x["deferred"] for x in summaries.values()),
        "failures": s["failures"], "deadline_hits": s["deadline_hits"],
        "retries": s["retries"], "breaker_trips": s["breaker_trips"],
        "degrades": s["degrades"],
        "recovery_p99_ms": s["recovery_p99_ms"],
        "elapsed_s": counters["elapsed_s"],
    }
    out.update(extra or {})
    return out


def _run_scenarios(nets, *, n_events, block, seed) -> dict:
    from repro.launch.mesh import make_serving_mesh
    from repro import backends as lut_backends
    from repro.serve import FaultInjector, FaultPlan, FaultSpec
    from repro.serve.registry import make_reference
    from repro.serve.supervision import ResiliencePolicy

    tenants = list(nets)
    t0_scope = tenants[0]
    cells = {}

    # 1. transient executor exceptions: retry + backoff absorbs them, the
    #    breaker never trips
    inj = FaultInjector(FaultPlan(
        [FaultSpec("exception", at=1, scope=t0_scope, count=2)]))
    policy = ResiliencePolicy(backoff_base_s=0.0, breaker_threshold=3)
    _, summ, ctr = _faulted_replay(nets, inj, policy, n_events=n_events,
                                   block=block, seed=seed)
    s = summ[t0_scope]
    cells["exception_transient"] = _cell(
        "exception_transient", "exception", inj, summ, ctr, t0_scope,
        detected=s["failures"],
        recovered=s["incidents_recovered"] >= 1 and s["breaker_trips"] == 0
        and ctr["lost"] == 0)

    # 2. persistent exceptions: breaker trips and the lane degrades from
    #    the fused-class backend onto the fallback, bit-identically
    inj = FaultInjector(FaultPlan(
        [FaultSpec("exception", at=0, scope=t0_scope, count=3)]))
    _, summ, ctr = _faulted_replay(nets, inj, policy, n_events=n_events,
                                   block=block, seed=seed,
                                   backends={t0_scope: "onehot"})
    s = summ[t0_scope]
    cells["breaker_degrade"] = _cell(
        "breaker_degrade", "exception", inj, summ, ctr, t0_scope,
        detected=s["failures"],
        recovered=s["degrades"] >= 1 and ctr["lost"] == 0,
        extra={"degrade_history": s["degrade_history"]})

    # 3. executor hang: the deadline abandons the stuck block and the
    #    rows recompute (fault-clock skew, no real sleeping)
    deadline = ResiliencePolicy(deadline_s=0.5, backoff_base_s=0.0,
                                breaker_threshold=3)
    inj = FaultInjector(FaultPlan(
        [FaultSpec("hang", at=1, scope=t0_scope, stall_s=2.0)]))
    _, summ, ctr = _faulted_replay(nets, inj, deadline, n_events=n_events,
                                   block=block, seed=seed)
    s = summ[t0_scope]
    cells["hang_deadline"] = _cell(
        "hang_deadline", "hang", inj, summ, ctr, t0_scope,
        detected=s["deadline_hits"],
        recovered=s["deadline_hits"] >= 1 and ctr["lost"] == 0)

    # 4. slow-start stall on the lane-dispatch seam: same deadline
    #    mechanics, different seam
    inj = FaultInjector(FaultPlan(
        [FaultSpec("slow_start", at=0, scope=t0_scope, stall_s=2.0)]))
    _, summ, ctr = _faulted_replay(nets, inj, deadline, n_events=n_events,
                                   block=block, seed=seed)
    s = summ[t0_scope]
    cells["slow_start"] = _cell(
        "slow_start", "slow_start", inj, summ, ctr, t0_scope,
        detected=s["deadline_hits"],
        recovered=s["deadline_hits"] >= 1 and ctr["lost"] == 0)

    # 5. device loss on a placed lane: the executor's device dies for
    #    good; the lane re-plans off the dead placement (sole-device mesh
    #    -> unplaced fallback; the N-way remesh is covered in
    #    tests/test_faults.py where subprocess device counts are cheap)
    inj = FaultInjector(FaultPlan(
        [FaultSpec("device_loss", at=1, scope=t0_scope)]))
    pl = lut_backends.Placement(make_serving_mesh(1))
    _, summ, ctr = _faulted_replay(nets, inj, policy, n_events=n_events,
                                   block=block, seed=seed,
                                   placements={t0_scope: pl})
    s = summ[t0_scope]
    cells["device_loss"] = _cell(
        "device_loss", "device_loss", inj, summ, ctr, t0_scope,
        detected=s["failures"],
        recovered=s["degrades"] >= 1 and ctr["lost"] == 0,
        extra={"degrade_history": s["degrade_history"]})

    # 6. corrupt deploy candidate racing the recovery: the injector flips
    #    table bits in the freshly loaded artifact; the smoke check must
    #    reject it and the incumbent must keep serving
    inj = FaultInjector(FaultPlan([
        FaultSpec("exception", at=0, scope=t0_scope, count=3),
        FaultSpec("corrupt_artifact", at=0, scope=t0_scope),
    ]))
    events = {}

    def _deploy(fleet, _events=events):
        net = nets[t0_scope]
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, "v2.npz")
            net.save(path)
            _events["swap"] = fleet.deploy(
                t0_scope, path, reference=make_reference(net, n=16))

    _, summ, ctr = _faulted_replay(
        nets, inj, policy, n_events=n_events, block=block, seed=seed,
        backends={t0_scope: "onehot"},
        at_event={max(1, n_events // 2): _deploy})
    s = summ[t0_scope]
    swap = events.get("swap")
    rejected = swap is not None and not swap.ok
    cells["corrupt_artifact"] = _cell(
        "corrupt_artifact", "corrupt_artifact", inj, summ, ctr, t0_scope,
        detected=1 if rejected else 0,
        recovered=rejected and s["version"] == 1 and ctr["lost"] == 0,
        extra={"deploy_rejected": rejected,
               "deploy_reason": swap.reason if swap else "deploy not run",
               "serving_version": s["version"]})
    return cells


def _degraded_throughput(nets, *, n_events, block, seed) -> dict:
    """Same trace clean vs through a trip-and-degrade incident; the
    degraded/clean rows-per-second ratio is the throughput floor."""
    from repro.serve import FaultInjector, FaultPlan, FaultSpec
    from repro.serve.supervision import ResiliencePolicy

    t0_scope = list(nets)[0]
    policy = ResiliencePolicy(backoff_base_s=0.0, breaker_threshold=3)
    _, summ, ctr = _faulted_replay(
        nets, None, policy, n_events=n_events, block=block, seed=seed,
        backends={t0_scope: "onehot"})
    clean_rows = sum(s["completed"] for s in summ.values())
    clean = clean_rows / max(ctr["elapsed_s"], 1e-9)

    inj = FaultInjector(FaultPlan(
        [FaultSpec("exception", at=0, scope=t0_scope, count=3)]))
    _, summ, ctr = _faulted_replay(
        nets, inj, policy, n_events=n_events, block=block, seed=seed,
        backends={t0_scope: "onehot"})
    deg_rows = sum(s["completed"] for s in summ.values())
    degraded = deg_rows / max(ctr["elapsed_s"], 1e-9)
    return {
        "clean_rows_per_s": round(clean, 1),
        "degraded_rows_per_s": round(degraded, 1),
        "throughput_ratio": round(degraded / max(clean, 1e-9), 4),
        "floor": 0.05,
        "wrong": ctr["wrong"], "lost": ctr["lost"],
        "degrades": summ[t0_scope]["degrades"],
    }


# ---------------------------------------------------------------------------
# stream failover under churn

def _stream_failover(comp, backend, *, n_events, block, seed,
                     checkpoint_every=8) -> dict:
    """Churned stream traffic through the replicated tenant; the primary
    dies mid-trace with blocks in flight, the standby takes over, and the
    remaining trace replays on the recovered fleet.  Every stream's
    combined (primary-delivered + standby-recomputed) codes must match
    the uninterrupted ``predict_sequence`` scan."""
    from repro.serve import LUTFleet
    from repro.stream.replica import ReplicatedStreamTenant, StandbyReplica

    trace = traffic.stream_churn_trace(["cell"], n_events=n_events,
                                       seed=seed)
    inputs = traffic.make_stream_inputs(trace, {"cell": comp.cell.n_in},
                                        seed=seed + 1)
    seqs = traffic.stream_sequences(trace, inputs)
    kill = max(2, (2 * len(trace)) // 3)

    primary = LUTFleet(block=block)
    primary.register("cell", comp, block=block, backend=backend)
    standby = StandbyReplica("cell", comp, block=block, backend=backend)
    tenant = ReplicatedStreamTenant(primary, "cell", standby,
                                    checkpoint_every=checkpoint_every)
    for ev, x in zip(trace[:kill], inputs[:kill]):
        if ev.action == "open":
            tenant.open_stream(ev.stream_id)
        elif ev.action == "feed":
            tenant.submit(ev.stream_id, x)
        else:
            tenant.close_stream(ev.stream_id)
        for _ in range(1 + ev.gap_ticks):
            primary.tick()
        tenant.maybe_checkpoint()
    primary.tick()      # partial progress past the checkpoint, then DEATH
    lane1 = primary._stream_lane("cell")
    pre = {sid: [np.asarray(r.codes) for r in s.steps]
           for sid, s in lane1.sessions.items()}
    ckpt = standby.checkpoint

    t0 = time.perf_counter()
    fleet2, replayed = standby.activate()
    fleet2.pump()       # the acked tail recomputes here
    recovery_ms = (time.perf_counter() - t0) * 1e3

    for ev, x in zip(trace[kill:], inputs[kill:]):
        if ev.action == "open":
            fleet2.open_stream("cell", ev.stream_id)
        elif ev.action == "feed":
            fleet2.submit_stream("cell", ev.stream_id, x)
        else:
            fleet2.close_stream("cell", ev.stream_id)
        for _ in range(1 + ev.gap_ticks):
            fleet2.tick()
    fleet2.pump()

    lane2 = fleet2._stream_lane("cell")
    mismatched = lost = steps = 0
    for (_, sid), xs_full in seqs.items():
        ref = np.asarray(comp.predict_sequence(xs_full[None],
                                               backend=backend)[0])[0]
        if sid in lane2.sessions:
            applied = (ckpt.applied_for(sid)
                       if ckpt is not None and sid in ckpt.stream_ids
                       else 0)
            combined = (pre.get(sid, [])[:applied]
                        + [np.asarray(r.codes)
                           for r in lane2.sessions[sid].steps])
        else:
            # never restored: the primary finalized it before dying
            combined = pre.get(sid, [])
        lost += max(0, len(ref) - len(combined))
        ok = (len(combined) == len(ref)
              and all(np.array_equal(c, ref[t])
                      for t, c in enumerate(combined)))
        mismatched += 0 if ok else 1
        steps += len(ref)
    return {
        "backend": backend,
        "events": len(trace), "killed_at_event": kill,
        "streams": len(seqs), "steps": steps,
        "checkpoints_shipped": standby.checkpoints_received,
        "replayed_steps": int(sum(replayed.values())),
        "restored_streams": len(replayed),
        "recovery_ms": round(recovery_ms, 3),
        "mismatched_streams": mismatched,
        "lost_steps": lost,
    }


def _make_cell(seed: int):
    from benchmarks import stream_serving
    return stream_serving._make_cell(seed, False)[3]


# ---------------------------------------------------------------------------
# seeded combined soak

def _soak(nets, *, n_events, block, seed) -> dict:
    from repro.serve import FaultInjector, FaultPlan
    from repro.serve.supervision import ResiliencePolicy

    plan = FaultPlan.seeded(seed, scopes=tuple(nets),
                            kinds=("exception", "hang", "slow_start"),
                            n_faults=12, max_at=80, stall_s=1.0)
    inj = FaultInjector(plan)
    # soft breaker: targeted scenarios already exercise trip/degrade, the
    # soak measures the whole stack absorbing a mixed schedule — onehot
    # lanes keep one degrade level in reserve should a trip still happen
    policy = ResiliencePolicy(deadline_s=0.5, max_retries=10,
                              backoff_base_s=0.0, breaker_threshold=10)
    _, summ, ctr = _faulted_replay(
        nets, inj, policy, n_events=n_events, block=block, seed=seed,
        backends={t: "onehot" for t in nets})
    return {
        "events": n_events,
        "faults_planned": len(plan.specs),
        "injected": {k: inj.fired(k)
                     for k in ("exception", "hang", "slow_start")},
        "accepted": ctr["accepted"],
        "completed": sum(s["completed"] for s in summ.values()),
        "wrong": ctr["wrong"], "lost": ctr["lost"],
        "detected": {
            "failures": sum(s["failures"] for s in summ.values()),
            "deadline_hits": sum(s["deadline_hits"] for s in summ.values()),
            "retries": sum(s["retries"] for s in summ.values()),
            "breaker_trips": sum(s["breaker_trips"] for s in summ.values()),
            "degrades": sum(s["degrades"] for s in summ.values()),
        },
        "recovery_p99_ms": max(s["recovery_p99_ms"] for s in summ.values()),
        "elapsed_s": ctr["elapsed_s"],
    }


# ---------------------------------------------------------------------------

def sweep(tasks=("nid", "jsc"), *, n_events: int = 36,
          soak_events: int = 120, stream_events: int = 60,
          block: int = 32, seed: int = 0,
          stream_backends=("take", "fused")) -> dict:
    nets = _make_nets(tasks, seed)
    results = {
        "schema_version": SCHEMA_VERSION,
        "generated_unix": time.time(),
        "params": {"tasks": list(tasks), "n_events": n_events,
                   "soak_events": soak_events,
                   "stream_events": stream_events, "block": block,
                   "seed": seed,
                   "stream_backends": list(stream_backends)},
        "scenarios": _run_scenarios(nets, n_events=n_events, block=block,
                                    seed=seed),
        "degraded": _degraded_throughput(nets, n_events=n_events,
                                         block=block, seed=seed),
    }
    comp = _make_cell(seed)
    results["stream_failover"] = {
        be: _stream_failover(comp, be, n_events=stream_events, block=block,
                             seed=seed)
        for be in stream_backends}
    results["soak"] = _soak(nets, n_events=soak_events, block=block,
                            seed=seed)
    return results


def contract_violations(results: dict) -> list:
    """The chaos serving contract, shared with check_regression."""
    bad = []
    for name, sc in results["scenarios"].items():
        if sc["wrong"]:
            bad.append(f"{name}: {sc['wrong']} wrong answers")
        if sc["lost"]:
            bad.append(f"{name}: {sc['lost']} accepted requests lost")
        if sc["injected"] == 0:
            bad.append(f"{name}: fault never injected")
        elif sc["detected"] == 0:
            bad.append(f"{name}: fault injected but never detected")
        if not sc["recovered"]:
            bad.append(f"{name}: lane did not recover")
    d = results["degraded"]
    if d["wrong"] or d["lost"]:
        bad.append(f"degraded: {d['wrong']} wrong / {d['lost']} lost")
    if d["throughput_ratio"] < d["floor"]:
        bad.append(f"degraded throughput ratio {d['throughput_ratio']} "
                   f"below the {d['floor']} floor")
    for be, r in results["stream_failover"].items():
        if r["checkpoints_shipped"] < 1:
            bad.append(f"stream_failover[{be}]: no checkpoint ever shipped")
        if r["mismatched_streams"]:
            bad.append(f"stream_failover[{be}]: {r['mismatched_streams']} "
                       "streams not bit-identical after failover")
        if r["lost_steps"]:
            bad.append(f"stream_failover[{be}]: {r['lost_steps']} acked "
                       "steps lost")
    s = results["soak"]
    if s["wrong"] or s["lost"]:
        bad.append(f"soak: {s['wrong']} wrong / {s['lost']} lost")
    if (sum(s["injected"].values())
            and not (s["detected"]["failures"]
                     + s["detected"]["deadline_hits"])):
        bad.append("soak: faults injected but none detected")
    return bad


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--long", action="store_true",
                    help="nightly long-soak plan (10x the traffic)")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()
    if args.fast and args.long:
        raise SystemExit("--fast and --long are mutually exclusive")

    kw = FAST_KW if args.fast else LONG_KW if args.long else {}
    results = sweep(**kw)
    out = write_results(results, args.out)

    print("scenario,kind,injected,detected,recovered,wrong,lost,"
          "recovery_p99_ms")
    for name, sc in results["scenarios"].items():
        print(f"{name},{sc['kind']},{sc['injected']},{sc['detected']},"
              f"{sc['recovered']},{sc['wrong']},{sc['lost']},"
              f"{sc['recovery_p99_ms']}")
    d = results["degraded"]
    print(f"degraded throughput ratio {d['throughput_ratio']} "
          f"({d['degraded_rows_per_s']} vs {d['clean_rows_per_s']} rows/s)")
    for be, r in results["stream_failover"].items():
        print(f"stream_failover[{be}]: {r['streams']} streams / "
              f"{r['steps']} steps, {r['replayed_steps']} replayed, "
              f"recovery {r['recovery_ms']}ms, "
              f"{r['mismatched_streams']} mismatched, "
              f"{r['lost_steps']} lost")
    s = results["soak"]
    print(f"soak: {sum(s['injected'].values())} faults over {s['events']} "
          f"events, {s['wrong']} wrong, {s['lost']} lost")

    bad = contract_violations(results)
    if bad:
        raise SystemExit("chaos serving contract violated:\n  "
                         + "\n  ".join(bad))
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
