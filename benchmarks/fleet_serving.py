"""Multi-tenant fleet serving sweep -> ``experiments/BENCH_fleet.json``.

The PR-6 acceptance benchmark (DESIGN.md §9): N models x M request streams
replayed through ONE :class:`~repro.serve.fleet.LUTFleet` versus N isolated
``LUTEngine`` deployments.

  * **online throughput** (the headline): the same ragged arrival trace is
    served ARRIVAL-DRIVEN.  An isolated per-model engine reacts to each of
    its arrivals (``submit; tick`` — the engine's contract dispatches
    whatever is queued, so ragged events become underfilled padded blocks,
    and every padded row is wasted lookup compute).  The fleet's scheduler
    owns the pump cadence instead: with ``min_fill=block`` it coalesces
    arrivals into FULL blocks across the whole fleet, dispatching a
    fraction of the blocks for the same rows.
    ``online.speedup_vs_isolated_sync`` must stay > 1 — the win is
    structural (fewer padded blocks, visible in ``blocks`` / ``rows_padded``
    per mode), not a machine-noise artifact, and holds on a single core.
  * **offline parity**: with everything queued up front both deployments
    batch perfectly; the fleet's scheduling overhead must stay within a
    few percent of isolated engines (``offline.fleet_vs_isolated_sync``).
  * **bit-identity**: every tenant's fleet-served codes are compared
    against its artifact's single-engine reference codes, per event.
  * **hot swap under live load**: a good deploy lands mid-stream with zero
    dropped requests and zero wrong answers; a corrupted artifact (table
    rows perturbed) is rejected and the rollback recorded.
  * **admission**: a tenant with a tight queue budget sheds load; the
    shed count is reported, not silently absorbed.

CPU numbers are structural (same caveat as lut_throughput); the gate in
``check_regression.py --suite fleet`` compares them cell-by-cell.

    PYTHONPATH=src python -m benchmarks.fleet_serving [--fast] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time

# tests/traffic.py is the shared ragged-trace generator (pure numpy, no
# package): pytest sees it via rootdir, benchmarks via this explicit insert
TESTS = os.path.join(os.path.dirname(__file__), "..", "tests")
if TESTS not in sys.path:
    sys.path.insert(0, TESTS)
import traffic  # noqa: E402

DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "..", "experiments",
                           "BENCH_fleet.json")
SCHEMA_VERSION = 1
# the one definition of "smoke-sized" (CI perf-gate and run.py --fast):
# reduced nets + a short trace keep it CPU-cheap; the >1 speedup gate only
# applies to full runs (a 24-event trace leaves too few blocks per mode
# for the ratio to be stable on a loaded CI host)
FAST_KW = dict(n_events=24, reps=2, block=128, full=False)


def write_results(results: dict, out: str = DEFAULT_OUT) -> str:
    out = os.path.abspath(out)
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(results, f, indent=2)
    return out


def _make_nets(tasks, seed: int, full: bool):
    import jax

    from repro import pipeline
    from repro.configs import paper_tasks
    from repro.core import assemble

    # full Table-II architectures for the committed sweep: every padded
    # row in an underfilled block then wastes REAL lookup compute, which
    # is exactly what the online coalescing headline quantifies; reduced
    # variants keep the CI smoke sizes cheap
    full_cfgs = {"nid": paper_tasks.nid, "jsc": paper_tasks.jsc_openml,
                 "mnist": paper_tasks.mnist}
    nets = {}
    for i, task in enumerate(tasks):
        cfg = full_cfgs[task]() if full else paper_tasks.reduced(task)
        params = assemble.init(jax.random.PRNGKey(seed + i), cfg)
        nets[task] = pipeline.compile_network(params, cfg)
    return nets


def _replay_fleet_online(fleet, trace, inputs):
    """Arrival-driven: submit each event then tick once (the pump runs at
    the fleet's own cadence; lanes below min_fill hold for fuller blocks),
    pump the tail.  Returns (elapsed_s, reqs_by_event)."""
    t0 = time.perf_counter()
    reqs_by_event = []
    for ev, xs in zip(trace, inputs):
        reqs, _ = fleet.submit_many(ev.model_id, xs)
        reqs_by_event.append(reqs)
        fleet.tick()
    fleet.pump()
    return time.perf_counter() - t0, reqs_by_event


def _replay_isolated_online(engines, trace, inputs):
    """Arrival-driven baseline: each model's engine serves its arrivals as
    they come (``submit; tick`` — the engine contract dispatches whatever
    is queued, so ragged events become underfilled padded blocks)."""
    t0 = time.perf_counter()
    for ev, xs in zip(trace, inputs):
        eng = engines[ev.model_id]
        eng.submit_many(xs)
        eng.tick()
    for eng in engines.values():
        while eng.queue:
            eng.tick()
        eng.drain()
    return time.perf_counter() - t0


def _replay_fleet_offline(fleet, trace, inputs):
    """Queue everything, then pump to idle (perfect-batching discipline)."""
    t0 = time.perf_counter()
    for ev, xs in zip(trace, inputs):
        fleet.submit_many(ev.model_id, xs)
    fleet.pump()
    return time.perf_counter() - t0


def _replay_isolated_offline(engines, trace, inputs):
    """Queue everything per model, run models back to back."""
    t0 = time.perf_counter()
    for model_id, eng in engines.items():
        for ev, xs in zip(trace, inputs):
            if ev.model_id == model_id:
                eng.submit_many(xs)
        while eng.queue:
            eng.tick()
        eng.drain()
    return time.perf_counter() - t0


def _block_counters(stats_list):
    return (sum(s.ticks for s in stats_list),
            sum(s.rows_padded for s in stats_list))


def sweep(tasks=("nid", "jsc", "mnist"), n_events: int = 128,
          block: int = 256, depth: int = 2, reps: int = 8,
          seed: int = 0, full: bool = True) -> dict:
    import numpy as np

    from repro.serve import LUTFleet, make_reference
    from repro.serve.lut_engine import LUTEngine

    nets = _make_nets(tasks, seed, full)
    in_features = {t: n.cfg.in_features for t, n in nets.items()}
    # gap-free trace: the timed comparison is pure throughput (idle ticks
    # would just add equal dead time to every mode)
    trace = traffic.ragged_trace(tasks, n_events=n_events, seed=seed + 10,
                                 gap_prob=0.0)
    inputs = traffic.make_inputs(trace, in_features, seed=seed + 11)
    rows_total = traffic.total_rows(trace)

    def _fleet(min_fill):
        fl = LUTFleet(block=block, depth=depth, min_fill=min_fill)
        for task, net in nets.items():
            fl.register(task, net, reference=make_reference(net))
        return fl

    # the online fleet holds lanes below a full block (batching-delay
    # policy); offline everything is queued up front, so min_fill is moot
    fleet_on, fleet_off = _fleet(block), _fleet(1)

    def _engines(d):
        return {t: LUTEngine(n, block=block, depth=d)
                for t, n in nets.items()}

    iso_sync, iso_async = _engines(1), _engines(2)

    # warm every jitted block function out of the timed region
    _replay_fleet_online(fleet_on, trace, inputs)
    _replay_fleet_offline(fleet_off, trace, inputs)
    _replay_isolated_online(iso_sync, trace, inputs)
    _replay_isolated_online(iso_async, trace, inputs)

    # dispatch counts are deterministic per discipline (same trace every
    # replay), so one counted replay each tells the structural story:
    # fewer, fuller blocks for the fleet under arrival-driven pumping
    fleet_stats = [fleet_on.stats(t) for t in tasks]
    iso_stats = [e.stats for e in iso_sync.values()]
    f0, i0 = _block_counters(fleet_stats), _block_counters(iso_stats)
    _replay_fleet_online(fleet_on, trace, inputs)
    _replay_isolated_online(iso_sync, trace, inputs)
    f1, i1 = _block_counters(fleet_stats), _block_counters(iso_stats)
    counters = {
        "fleet_blocks": f1[0] - f0[0],
        "fleet_rows_padded": f1[1] - f0[1],
        "isolated_blocks": i1[0] - i0[0],
        "isolated_rows_padded": i1[1] - i0[1],
    }

    # best-of reps, modes interleaved (same rationale as lut_throughput:
    # the speedup ratio is the headline; phase skew would manufacture one)
    best: dict = {}
    reqs_by_event = None

    def _note(mode, dt):
        if mode not in best or dt < best[mode]:
            best[mode] = dt
            return True
        return False

    for _ in range(max(reps, 1)):
        dt, reqs = _replay_fleet_online(fleet_on, trace, inputs)
        if _note("on_fleet", dt):
            reqs_by_event = reqs
        _note("on_sync", _replay_isolated_online(iso_sync, trace, inputs))
        _note("on_async", _replay_isolated_online(iso_async, trace, inputs))
        _note("off_fleet", _replay_fleet_offline(fleet_off, trace, inputs))
        _note("off_sync", _replay_isolated_offline(iso_sync, trace, inputs))
        _note("off_async", _replay_isolated_offline(iso_async, trace, inputs))

    # bit-identity of the best ONLINE fleet replay vs single-engine
    # reference codes, per tenant per event
    per_tenant = []
    for task, net in nets.items():
        identical = True
        task_rows = 0
        for ev, xs, reqs in zip(trace, inputs, reqs_by_event):
            if ev.model_id != task:
                continue
            task_rows += len(xs)
            ref = np.asarray(net.predict_codes(xs))
            got = np.stack([r.codes for r in reqs])
            identical &= bool(np.array_equal(got, ref))
        s = fleet_on.summary(task)
        per_tenant.append({
            "model_id": task, "rows_per_replay": task_rows,
            "bit_identical": identical,
            "p50_request_us": s["p50_request_us"],
            "p99_request_us": s["p99_request_us"],
        })

    def _rate(dt):
        return round(rows_total / dt, 1)

    results = {
        "schema_version": SCHEMA_VERSION,
        "tasks": list(tasks), "models": len(tasks), "streams": n_events,
        "rows_per_replay": rows_total, "block": block, "depth": depth,
        "min_fill": block, "full_size": full,
        "online": {
            "fleet_rows_per_s": _rate(best["on_fleet"]),
            "isolated_sync_rows_per_s": _rate(best["on_sync"]),
            "isolated_async_rows_per_s": _rate(best["on_async"]),
            "speedup_vs_isolated_sync": round(
                best["on_sync"] / best["on_fleet"], 3),
            "speedup_vs_isolated_async": round(
                best["on_async"] / best["on_fleet"], 3),
            **counters,
        },
        "offline": {
            "fleet_rows_per_s": _rate(best["off_fleet"]),
            "isolated_sync_rows_per_s": _rate(best["off_sync"]),
            "isolated_async_rows_per_s": _rate(best["off_async"]),
            "fleet_vs_isolated_sync": round(
                best["off_sync"] / best["off_fleet"], 3),
        },
        "per_tenant": per_tenant,
    }

    results["hot_swap"] = _hot_swap_under_load(nets, tasks[0], block, depth)
    results["admission"] = _admission_stress(nets, tasks[0], block)
    return results


def _hot_swap_under_load(nets, task: str, block: int, depth: int) -> dict:
    """Deploy a good v2 and a corrupted candidate while requests are queued
    and in flight; count drops, wrong answers, and the recorded rollback."""
    import numpy as np

    from repro.serve import LUTFleet, make_reference

    net = nets[task]
    ref = make_reference(net)
    fleet = LUTFleet(block=block, depth=depth)
    fleet.register(task, net, reference=ref)
    rng = np.random.default_rng(1)
    x = rng.uniform(-1.0, 1.0,
                    (4 * block, net.cfg.in_features)).astype(np.float32)
    reqs, _ = fleet.submit_many(task, x)
    fleet.tick()                          # blocks now in flight on v1

    with tempfile.TemporaryDirectory() as d:
        good = net.save(os.path.join(d, "v2.npz"))
        z = np.load(good)
        arrays = {k: z[k] for k in z.files}
        last = f"table_{len(net.cfg.layers) - 1}"
        arrays[last] = (arrays[last] ^ 1).astype(arrays[last].dtype)
        bad = os.path.join(d, "corrupt.npz")
        np.savez_compressed(bad, **arrays)

        ev_good = fleet.deploy(task, good, reference=ref)
        more, _ = fleet.submit_many(task, x[:block])   # lands on v2
        ev_bad = fleet.deploy(task, bad, reference=ref)
        fleet.pump()

    every = reqs + more
    dropped = sum(not r.done for r in every)
    expect = np.asarray(net.predict_codes(np.concatenate([x, x[:block]])))
    got = np.stack([r.codes for r in every])
    wrong = int((got != expect).any(axis=-1).sum())
    return {
        "good_deploy_ok": bool(ev_good.ok),
        "to_version": ev_good.to_version,
        "corrupt_deploy_rejected": bool(not ev_bad.ok),
        "rollback_recorded": bool(
            fleet.summary(task)["swap_history"][-1]["ok"] is False),
        "dropped": dropped,
        "wrong": wrong,
        "requests": len(every),
    }


def _admission_stress(nets, task: str, block: int) -> dict:
    """A tenant with a tight queue budget under a burst: load is shed at
    the door (counted), and everything admitted still completes."""
    import numpy as np

    from repro.serve import LUTFleet, TenantSLO, make_reference

    net = nets[task]
    budget = 2 * block
    fleet = LUTFleet(block=block)
    fleet.register(task, net, reference=make_reference(net),
                   slo=TenantSLO(max_queue=budget, policy="shed"))
    rng = np.random.default_rng(2)
    x = rng.uniform(-1.0, 1.0,
                    (4 * block, net.cfg.in_features)).astype(np.float32)
    _, decision = fleet.submit_many(task, x)
    fleet.pump()
    s = fleet.summary(task)
    return {"max_queue": budget, "offered": len(x),
            "accepted": decision.accept, "shed": s["shed"],
            "completed": s["completed"]}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smoke-sized sweep (CI perf-gate)")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()

    results = sweep(**(FAST_KW if args.fast else {}))
    out = write_results(results, args.out)

    on, off = results["online"], results["offline"]
    print("discipline,mode,rows_per_s")
    print(f"online,fleet,{on['fleet_rows_per_s']}")
    print(f"online,isolated_sync,{on['isolated_sync_rows_per_s']}")
    print(f"online,isolated_async,{on['isolated_async_rows_per_s']}")
    print(f"offline,fleet,{off['fleet_rows_per_s']}")
    print(f"offline,isolated_sync,{off['isolated_sync_rows_per_s']}")
    print(f"online speedup_vs_isolated_sync={on['speedup_vs_isolated_sync']}"
          f" (blocks {on['fleet_blocks']} vs {on['isolated_blocks']}, "
          f"rows_padded {on['fleet_rows_padded']} vs "
          f"{on['isolated_rows_padded']})")
    print(f"offline fleet_vs_isolated_sync={off['fleet_vs_isolated_sync']}")
    print("model,bit_identical,p99_request_us")
    for t in results["per_tenant"]:
        print(f"{t['model_id']},{t['bit_identical']},{t['p99_request_us']}")
    hs = results["hot_swap"]
    print(f"hot_swap ok={hs['good_deploy_ok']} dropped={hs['dropped']} "
          f"wrong={hs['wrong']} "
          f"corrupt_rejected={hs['corrupt_deploy_rejected']}")
    print(f"admission shed={results['admission']['shed']}/"
          f"{results['admission']['offered']}")

    bad = [t["model_id"] for t in results["per_tenant"]
           if not t["bit_identical"]]
    if bad:
        raise SystemExit(f"fleet codes NOT bit-identical for: {bad}")
    if hs["dropped"] or hs["wrong"] or not hs["corrupt_deploy_rejected"]:
        raise SystemExit(f"hot-swap contract violated: {hs}")
    if not args.fast and on["speedup_vs_isolated_sync"] <= 1.0:
        raise SystemExit(
            "online fleet did not beat isolated sync engines: "
            f"{on['speedup_vs_isolated_sync']}")
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
