"""Roofline analysis over the dry-run records (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh) cell, three per-device time lower bounds on
TPU v5e:

  compute    = dot_FLOPs_per_device / 197e12           (bf16 peak)
  memory     = bytes_accessed_per_device / 819e9       (HBM bw)
  collective = wire_bytes_per_device / 50e9            (per-link ICI)

Sources: dot FLOPs and collective payloads from the loop-corrected HLO
walker (benchmarks/hlo_analysis — cost_analysis counts while bodies once);
bytes-accessed from compiled.cost_analysis() corrected by the same loop
multiplier implied by the flops ratio.  Wire factors per algorithm:
all-reduce 2(n-1)/n, all-gather/reduce-scatter/all-to-all (n-1)/n,
collective-permute 1 (payloads are already per-device tensor bytes).

MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) for training; 2*N*D per
generated/prefilled token for inference cells.  The ratio
MODEL_FLOPS / HLO_FLOPs shows how much compiled compute is "useful"
(catching remat/dispatch waste).
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                           "dryrun")

WIRE_FACTOR = {
    "all-reduce": 2.0,        # ring: 2(n-1)/n ~= 2
    "all-gather": 1.0,        # (n-1)/n ~= 1
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def load_records(results_dir: Optional[str] = None) -> List[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(results_dir or RESULTS_DIR,
                                              "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def model_flops(cfg, shape) -> float:
    """Analytic useful-FLOPs for the cell (global, forward+backward for
    train; forward for serve)."""
    n_active = cfg.n_active_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def cell_roofline(rec: dict, cfg, shape) -> Optional[dict]:
    if rec.get("status") != "ok":
        return None
    ana = rec.get("analysis") or {}
    flops_dev = ana.get("dot_flops_per_device", 0.0)
    coll = ana.get("collective_bytes_per_device", {})
    wire_bytes = sum(WIRE_FACTOR[k] * v for k, v in coll.items()
                     if k in WIRE_FACTOR)
    # bytes accessed: cost_analysis is loop-body-once; scale by the same
    # multiplier the flop walker implies (bounded to >= 1).
    ca = rec.get("cost_analysis", {})
    raw_flops = ca.get("flops", 0.0) or 1.0
    mult = max(1.0, flops_dev / raw_flops) if raw_flops else 1.0
    bytes_dev = (ca.get("bytes accessed", 0.0) or 0.0) * mult

    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = wire_bytes / ICI_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    hlo_flops_global = flops_dev * rec["devices"]
    bound = max(terms.values())
    ideal = mf / (rec["devices"] * PEAK_FLOPS)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "devices": rec["devices"],
        **{k: round(v, 6) for k, v in terms.items()},
        "dominant": dominant.replace("_s", ""),
        "model_flops": mf,
        "hlo_flops_global": hlo_flops_global,
        "useful_ratio": round(mf / hlo_flops_global, 3)
        if hlo_flops_global else None,
        # fraction of ideal (pure-compute) step time if the dominant
        # bound were achieved:
        "roofline_fraction": round(ideal / bound, 3) if bound else None,
        "loop_mult": round(mult, 1),
    }


def build_table(results_dir: Optional[str] = None) -> List[dict]:
    from repro.configs import lm_archs
    from repro.launch import steps

    rows = []
    for rec in load_records(results_dir):
        cfg = lm_archs.get(rec["arch"])
        shape = steps.SHAPES[rec["shape"]]
        if rec.get("status") == "skipped":
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "mesh": rec["mesh"], "status": "skipped",
                         "reason": rec.get("reason", "")})
            continue
        row = cell_roofline(rec, cfg, shape)
        if row:
            row["status"] = "ok"
            rows.append(row)
        else:
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "mesh": rec["mesh"], "status": rec.get("status")})
    return rows


def print_table(mesh: str = "single",
                results_dir: Optional[str] = None) -> List[dict]:
    rows = [r for r in build_table(results_dir) if r["mesh"] == mesh]
    hdr = (f"{'arch':<15} {'shape':<12} {'comp ms':>8} {'mem ms':>8} "
           f"{'coll ms':>8} {'dominant':>10} {'useful':>7} {'roofl%':>7}")
    print(hdr)
    print("-" * len(hdr))
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if r.get("status") != "ok":
            print(f"{r['arch']:<15} {r['shape']:<12} "
                  f"{'[' + r.get('status', '?') + ']':>8}")
            continue
        print(f"{r['arch']:<15} {r['shape']:<12} "
              f"{r['compute_s'] * 1e3:8.2f} {r['memory_s'] * 1e3:8.2f} "
              f"{r['collective_s'] * 1e3:8.2f} {r['dominant']:>10} "
              f"{r['useful_ratio'] if r['useful_ratio'] is not None else '-':>7} "
              f"{(r['roofline_fraction'] or 0) * 100:6.1f}%")
    return rows


def markdown_table(results_dir: Optional[str] = None) -> str:
    lines = []
    for mesh in ("single", "multi"):
        rows = [r for r in build_table(results_dir) if r["mesh"] == mesh]
        lines.append(f"\n#### mesh: {mesh}\n")
        lines.append("| arch | shape | compute ms | memory ms | "
                     "collective ms | dominant | useful | roofline |")
        lines.append("|---|---|---|---|---|---|---|---|")
        for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
            if r.get("status") != "ok":
                lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                             f"{r.get('status')} | — | — |")
                continue
            lines.append(
                f"| {r['arch']} | {r['shape']} | "
                f"{r['compute_s'] * 1e3:.1f} | {r['memory_s'] * 1e3:.1f} | "
                f"{r['collective_s'] * 1e3:.1f} | {r['dominant']} | "
                f"{r['useful_ratio']} | "
                f"{(r['roofline_fraction'] or 0) * 100:.1f}% |")
    return "\n".join(lines)


def main() -> None:
    import sys
    results_dir = sys.argv[1] if len(sys.argv) > 1 else None
    for mesh in ("single", "multi"):
        print(f"\n=== mesh: {mesh} ===")
        print_table(mesh, results_dir)


if __name__ == "__main__":
    main()
