"""Roofline analysis over the dry-run records (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh) cell, three per-device time lower bounds on
TPU v5e:

  compute    = dot_FLOPs_per_device / 197e12           (bf16 peak)
  memory     = bytes_accessed_per_device / 819e9       (HBM bw)
  collective = wire_bytes_per_device / 50e9            (per-link ICI)

Sources: dot FLOPs and collective payloads from the loop-corrected HLO
walker (benchmarks/hlo_analysis — cost_analysis counts while bodies once);
bytes-accessed from compiled.cost_analysis() corrected by the same loop
multiplier implied by the flops ratio.  Wire factors per algorithm:
all-reduce 2(n-1)/n, all-gather/reduce-scatter/all-to-all (n-1)/n,
collective-permute 1 (payloads are already per-device tensor bytes).

MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) for training; 2*N*D per
generated/prefilled token for inference cells.  The ratio
MODEL_FLOPS / HLO_FLOPs shows how much compiled compute is "useful"
(catching remat/dispatch waste).

``--lut`` switches to the LUT-cascade roofline (docs/KERNELS.md §5): it
prints the autotuner's modeled candidate grid — (mode, block_b,
unit_tile) x {compute roof, memory roof, VMEM feasibility} from
``repro.kernels.autotune.roofline_candidates`` — for each paper task, and
writes the per-(task, device) choice table to
``experiments/AUTOTUNE_choices.json`` (the nightly CI uploads it as the
autotuner audit artifact).  Pure model output: no training, no timing.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                           "dryrun")

WIRE_FACTOR = {
    "all-reduce": 2.0,        # ring: 2(n-1)/n ~= 2
    "all-gather": 1.0,        # (n-1)/n ~= 1
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def load_records(results_dir: Optional[str] = None) -> List[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(results_dir or RESULTS_DIR,
                                              "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def model_flops(cfg, shape) -> float:
    """Analytic useful-FLOPs for the cell (global, forward+backward for
    train; forward for serve)."""
    n_active = cfg.n_active_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def cell_roofline(rec: dict, cfg, shape) -> Optional[dict]:
    if rec.get("status") != "ok":
        return None
    ana = rec.get("analysis") or {}
    flops_dev = ana.get("dot_flops_per_device", 0.0)
    coll = ana.get("collective_bytes_per_device", {})
    wire_bytes = sum(WIRE_FACTOR[k] * v for k, v in coll.items()
                     if k in WIRE_FACTOR)
    # bytes accessed: cost_analysis is loop-body-once; scale by the same
    # multiplier the flop walker implies (bounded to >= 1).
    ca = rec.get("cost_analysis", {})
    raw_flops = ca.get("flops", 0.0) or 1.0
    mult = max(1.0, flops_dev / raw_flops) if raw_flops else 1.0
    bytes_dev = (ca.get("bytes accessed", 0.0) or 0.0) * mult

    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = wire_bytes / ICI_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    hlo_flops_global = flops_dev * rec["devices"]
    bound = max(terms.values())
    ideal = mf / (rec["devices"] * PEAK_FLOPS)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "devices": rec["devices"],
        **{k: round(v, 6) for k, v in terms.items()},
        "dominant": dominant.replace("_s", ""),
        "model_flops": mf,
        "hlo_flops_global": hlo_flops_global,
        "useful_ratio": round(mf / hlo_flops_global, 3)
        if hlo_flops_global else None,
        # fraction of ideal (pure-compute) step time if the dominant
        # bound were achieved:
        "roofline_fraction": round(ideal / bound, 3) if bound else None,
        "loop_mult": round(mult, 1),
    }


def build_table(results_dir: Optional[str] = None) -> List[dict]:
    from repro.configs import lm_archs
    from repro.launch import steps

    rows = []
    for rec in load_records(results_dir):
        cfg = lm_archs.get(rec["arch"])
        shape = steps.SHAPES[rec["shape"]]
        if rec.get("status") == "skipped":
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "mesh": rec["mesh"], "status": "skipped",
                         "reason": rec.get("reason", "")})
            continue
        row = cell_roofline(rec, cfg, shape)
        if row:
            row["status"] = "ok"
            rows.append(row)
        else:
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "mesh": rec["mesh"], "status": rec.get("status")})
    return rows


def print_table(mesh: str = "single",
                results_dir: Optional[str] = None) -> List[dict]:
    rows = [r for r in build_table(results_dir) if r["mesh"] == mesh]
    hdr = (f"{'arch':<15} {'shape':<12} {'comp ms':>8} {'mem ms':>8} "
           f"{'coll ms':>8} {'dominant':>10} {'useful':>7} {'roofl%':>7}")
    print(hdr)
    print("-" * len(hdr))
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if r.get("status") != "ok":
            print(f"{r['arch']:<15} {r['shape']:<12} "
                  f"{'[' + r.get('status', '?') + ']':>8}")
            continue
        print(f"{r['arch']:<15} {r['shape']:<12} "
              f"{r['compute_s'] * 1e3:8.2f} {r['memory_s'] * 1e3:8.2f} "
              f"{r['collective_s'] * 1e3:8.2f} {r['dominant']:>10} "
              f"{r['useful_ratio'] if r['useful_ratio'] is not None else '-':>7} "
              f"{(r['roofline_fraction'] or 0) * 100:6.1f}%")
    return rows


def markdown_table(results_dir: Optional[str] = None) -> str:
    lines = []
    for mesh in ("single", "multi"):
        rows = [r for r in build_table(results_dir) if r["mesh"] == mesh]
        lines.append(f"\n#### mesh: {mesh}\n")
        lines.append("| arch | shape | compute ms | memory ms | "
                     "collective ms | dominant | useful | roofline |")
        lines.append("|---|---|---|---|---|---|---|---|")
        for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
            if r.get("status") != "ok":
                lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                             f"{r.get('status')} | — | — |")
                continue
            lines.append(
                f"| {r['arch']} | {r['shape']} | "
                f"{r['compute_s'] * 1e3:.1f} | {r['memory_s'] * 1e3:.1f} | "
                f"{r['collective_s'] * 1e3:.1f} | {r['dominant']} | "
                f"{r['useful_ratio']} | "
                f"{(r['roofline_fraction'] or 0) * 100:.1f}% |")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# LUT-cascade roofline (--lut): the kernel autotuner's model, printed
# ---------------------------------------------------------------------------

LUT_CHOICES_OUT = os.path.join(os.path.dirname(__file__), "..",
                               "experiments", "AUTOTUNE_choices.json")


def print_lut_candidates(task: str = "nid", device: str = "cpu",
                         batch: int = 4096) -> List[dict]:
    """Print the modeled candidate grid for one task's fused cascade."""
    from repro.configs import paper_tasks
    from repro.kernels import autotune

    cfg = paper_tasks.task_config(task)
    layers, off = [], 0
    for l, spec in enumerate(cfg.layers):
        layers.append((cfg.prev_width(l), spec.units,
                       2 ** (cfg.in_bits(l) * spec.fan_in), off,
                       spec.fan_in, cfg.in_bits(l), int(spec.assemble)))
        off += spec.units
    rows = autotune.roofline_candidates(layers, batch=batch, device=device)
    pick = autotune.pick_tuning(layers, batch=batch, device=device)
    hdr = (f"{'mode':<9} {'block_b':>7} {'tile':>5} {'comp us':>8} "
           f"{'mem us':>8} {'bound':>7} {'rows/s':>12} {'vmem KiB':>9} "
           f"{'fits':>5}")
    print(f"\n=== {task} @ {device} (batch {batch}) ===")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        star = " *" if (r["mode"] == pick.mode
                        and r["block_b"] == pick.block_b
                        and (r["mode"] == "resident"
                             or r["unit_tile"] == pick.unit_tile)) else ""
        print(f"{r['mode']:<9} {r['block_b']:>7} "
              f"{r['unit_tile'] or '-':>5} {r['t_compute_us']:>8.2f} "
              f"{r['t_memory_us']:>8.2f} {r['bound']:>7} "
              f"{r['rows_per_s']:>12,.0f} {r['vmem_bytes'] / 1024:>9.0f} "
              f"{'y' if r['fits_vmem'] else 'N':>5}{star}")
    print(f"pick: mode={pick.mode} block_b={pick.block_b} "
          f"unit_tile={pick.unit_tile} (source={pick.source})")
    return rows


def write_lut_choices(out: str = LUT_CHOICES_OUT) -> str:
    """Write the per-(task, device) autotuner choice table (nightly CI)."""
    from repro.kernels import autotune

    doc = autotune.choice_table()
    out = os.path.abspath(out)
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    return out


def lut_main(out: str, tasks=None, devices=("cpu", "tpu")) -> None:
    from repro.configs import paper_tasks

    for task in tasks or sorted(paper_tasks.TASKS):
        for dev in devices:
            print_lut_candidates(task, dev)
    print(f"\nwrote {write_lut_choices(out)}")


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("results_dir", nargs="?", default=None,
                    help="dry-run records dir (LM roofline mode)")
    ap.add_argument("--lut", action="store_true",
                    help="LUT-cascade autotuner roofline instead of the "
                         "LM dry-run table")
    ap.add_argument("--out", default=LUT_CHOICES_OUT,
                    help="--lut: where to write the choice table JSON")
    args = ap.parse_args()
    if args.lut:
        lut_main(args.out)
        return
    for mesh in ("single", "multi"):
        print(f"\n=== mesh: {mesh} ===")
        print_table(mesh, args.results_dir)


if __name__ == "__main__":
    main()
