"""Stateful stream serving sweep -> ``experiments/BENCH_stream.json``.

The PR-7 acceptance benchmark (DESIGN.md §10): stateful streams over an
assembled-LUT recurrent cell, served by ONE cell-mode engine behind the
fleet's stream lane.

  * **concurrent-stream scaling** (the headline): N streams open at once,
    each fed ``t_steps`` recurrent steps; the router packs steps of
    different streams into full blocks, so concurrency — not per-stream
    depth — keeps the fixed-shape block function busy.  Reported per
    scale point: steps/s, per-step latency p50/p99 (submit -> retire),
    dispatched blocks / padded rows, and the live state footprint in
    bytes.  The full sweep must reach >= 1000 concurrent streams with
    every stream's served codes bit-identical to the offline
    full-sequence scan.
  * **churn bit-identity, per backend**: a churned trace (streams open,
    burst-feed, and close mid-trace — ``tests/traffic.py``) replayed per
    registered lookup backend; every stream's full sequence must match
    ``predict_sequence`` on that backend bit for bit.
  * **stateful hot swap**: a mid-flight deploy with an identical
    in-boundary carries live state verbatim (``carried``); a deploy whose
    input scale moved re-quantizes every live state (``requantized``).
    Both must drop zero steps and serve zero wrong answers.

CPU numbers are structural (same caveat as lut_throughput); the gate in
``check_regression.py --suite stream`` compares them cell-by-cell.

    PYTHONPATH=src python -m benchmarks.stream_serving [--fast] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time

# tests/traffic.py is the shared trace generator (pure numpy, no package):
# pytest sees it via rootdir, benchmarks via this explicit insert
TESTS = os.path.join(os.path.dirname(__file__), "..", "tests")
if TESTS not in sys.path:
    sys.path.insert(0, TESTS)
import traffic  # noqa: E402

DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "..", "experiments",
                           "BENCH_stream.json")
SCHEMA_VERSION = 1
# the one definition of "smoke-sized" (CI perf-gate and run.py --fast):
# a tiny cell + small stream counts keep it CPU-cheap; the >= 1000
# concurrent-stream floor only applies to full runs
FAST_KW = dict(scales=(16, 64), t_steps=4, reps=2, block=64,
               churn_events=24, swap_streams=8, full=False)


def write_results(results: dict, out: str = DEFAULT_OUT) -> str:
    out = os.path.abspath(out)
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(results, f, indent=2)
    return out


def _make_cell(seed: int, full: bool):
    import jax

    from repro.configs import paper_tasks
    from repro.core.assemble import AssembleConfig, LayerSpec
    from repro.stream import StreamCellConfig, compile_cell
    from repro.stream import cell as cell_mod

    # the full sweep streams the SeqMNIST task cell (the paper-aligned
    # sequential architecture); the smoke sweep uses a reduced cell so the
    # CI perf-gate stays cheap
    if full:
        cc = paper_tasks.stream_task_config("seqmnist_reduced")
        task = "seqmnist_reduced"
    else:
        net = AssembleConfig(
            in_features=6, input_bits=2, input_signed=False,
            layers=(LayerSpec(12, 3, 2, False), LayerSpec(4, 3, 2, True)),
            subnet_width=8, subnet_depth=2, skip_step=2)
        cc = StreamCellConfig(net=net, n_in=4, n_state=2)
        task = "reduced"
    params = cell_mod.init(jax.random.PRNGKey(seed), cc)
    return task, cc, params, compile_cell(params, cc)


def _stream_batch(n: int, t: int, n_in: int, seed: int):
    import numpy as np
    rng = np.random.default_rng(seed)
    return rng.uniform(0.0, 1.0, (n, t, n_in)).astype(np.float32)


def _replay_scale(comp, xs, block: int, depth: int):
    """Open one stream per row of ``xs [N, T, n_in]``, feed its whole
    sequence, pump to idle.  Returns (elapsed_s, fleet)."""
    from repro.serve import LUTFleet

    fleet = LUTFleet(block=block, depth=depth)
    fleet.register("cell", comp)
    t0 = time.perf_counter()
    for sid in range(len(xs)):
        fleet.open_stream("cell", sid)
        fleet.submit_stream("cell", sid, xs[sid])
    fleet.pump()
    return time.perf_counter() - t0, fleet


def _scaling_sweep(comp, scales, t_steps: int, block: int, depth: int,
                   reps: int, seed: int) -> list:
    import numpy as np

    points = []
    for n in scales:
        xs = _stream_batch(n, t_steps, comp.cell.n_in, seed + n)
        # warm replay (jit compile out of the timed region) doubles as the
        # bit-identity check: every stream vs ONE batched offline scan
        _, fleet = _replay_scale(comp, xs, block, depth)
        ref = np.asarray(comp.predict_sequence(xs)[0])
        lane = fleet._lanes["cell"]
        identical = all(
            np.array_equal(lane.sessions[sid].codes(), ref[sid])
            for sid in range(n))
        best_dt, best_fleet = None, None
        for _ in range(max(reps, 1)):
            dt, fl = _replay_scale(comp, xs, block, depth)
            if best_dt is None or dt < best_dt:
                best_dt, best_fleet = dt, fl
        s = best_fleet.summary("cell")
        lane = best_fleet._lanes["cell"]
        points.append({
            "streams": n, "steps": n * t_steps,
            "steps_per_s": round(n * t_steps / best_dt, 1),
            "p50_step_us": s["p50_request_us"],
            "p99_step_us": s["p99_request_us"],
            "blocks": s["ticks"], "rows_padded": s["rows_padded"],
            "state_bytes": lane.store.nbytes,
            "bit_identical": identical,
        })
    return points


def _churn_identity(comp, n_events: int, block: int, depth: int,
                    seed: int) -> dict:
    """Churned stream traffic per registered backend: open/burst/close
    mid-trace, then every stream's full sequence vs the offline scan."""
    import numpy as np

    from repro import backends
    from repro.serve import LUTFleet

    trace = traffic.stream_churn_trace(["cell"], n_events=n_events,
                                       seed=seed)
    inputs = traffic.make_stream_inputs(trace, {"cell": comp.cell.n_in},
                                        seed=seed + 1)
    seqs = traffic.stream_sequences(trace, inputs)
    per_backend = {}
    for be in backends.available():
        fleet = LUTFleet(block=block, depth=depth)
        fleet.register("cell", comp, backend=be)
        for ev, x in zip(trace, inputs):
            if ev.action == "open":
                fleet.open_stream("cell", ev.stream_id)
            elif ev.action == "feed":
                fleet.submit_stream("cell", ev.stream_id, x)
            else:
                fleet.close_stream("cell", ev.stream_id)
            for _ in range(ev.gap_ticks):
                fleet.tick()
        fleet.pump()
        lane = fleet._lanes["cell"]
        identical = True
        for (_, sid), xs in seqs.items():
            ref = np.asarray(comp.predict_sequence(xs[None],
                                                   backend=be)[0])[0]
            identical &= bool(np.array_equal(lane.sessions[sid].codes(),
                                             ref))
        s = fleet.summary("cell")
        per_backend[be] = {
            "bit_identical": identical,
            "completed": s["completed"],
            "dropped": s["requests"] - s["completed"],
        }
    return {"events": len(trace), "streams": len(seqs),
            "steps": int(sum(len(x) for x in seqs.values())),
            "per_backend": per_backend}


def _hot_swap_carried(comp, n_streams: int, t_steps: int, block: int,
                      depth: int, seed: int) -> dict:
    """Deploy v2 (identical tables, identical in-boundary) while steps are
    IN FLIGHT: live state carries verbatim, and every stream's complete
    sequence still matches the offline scan."""
    import numpy as np

    from repro.serve import LUTFleet

    xs = _stream_batch(n_streams, t_steps, comp.cell.n_in, seed)
    half = t_steps // 2
    fleet = LUTFleet(block=block, depth=depth)
    fleet.register("cell", comp)
    for sid in range(n_streams):
        fleet.open_stream("cell", sid)
        fleet.submit_stream("cell", sid, xs[sid, :half])
    fleet.tick()                                  # steps now in flight
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "v2.npz")
        comp.save(path)
        event = fleet.deploy("cell", path)
    for sid in range(n_streams):
        fleet.submit_stream("cell", sid, xs[sid, half:])
    fleet.pump()

    ref = np.asarray(comp.predict_sequence(xs)[0])
    lane = fleet._lanes["cell"]
    wrong = sum(
        int((lane.sessions[sid].codes() != ref[sid]).any(axis=-1).sum())
        for sid in range(n_streams))
    s = fleet.summary("cell")
    return {
        "streams": n_streams, "requests": s["requests"],
        "deploy_ok": bool(event.ok), "to_version": event.to_version,
        "state_migration": s["swap_history"][-1]["state_migration"],
        "dropped": s["requests"] - s["completed"], "wrong": wrong,
    }


def _hot_swap_requantized(comp, params, n_streams: int, t_steps: int,
                          block: int, depth: int, seed: int) -> dict:
    """Deploy a v2 whose input scale moved: every live stream's state is
    re-quantized onto the new boundary, and post-swap serving matches the
    new cell's own recurrence from the migrated state."""
    import jax
    import numpy as np

    from repro.serve import LUTFleet
    from repro.stream import compile_cell, migrate_state_codes

    params2 = dict(params, in_q={
        "log_scale": jax.numpy.asarray(params["in_q"]["log_scale"]) + 0.1})
    comp2 = compile_cell(params2, comp.cell)

    xs = _stream_batch(n_streams, t_steps, comp.cell.n_in, seed)
    half = t_steps // 2
    fleet = LUTFleet(block=block, depth=depth)
    fleet.register("cell", comp)
    for sid in range(n_streams):
        fleet.open_stream("cell", sid)
        fleet.submit_stream("cell", sid, xs[sid, :half])
    fleet.pump()                        # drain: the v1/v2 boundary is exact
    lane = fleet._lanes["cell"]
    s_before = np.stack([lane.store.get(sid) for sid in range(n_streams)])
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "v2.npz")
        comp2.save(path)
        event = fleet.deploy("cell", path)
    for sid in range(n_streams):
        fleet.submit_stream("cell", sid, xs[sid, half:])
    fleet.pump()

    s_mig = np.asarray(migrate_state_codes(comp, comp2, s_before))
    expect = np.asarray(comp2.predict_sequence(xs[:, half:],
                                               s0_codes=s_mig)[0])
    wrong = sum(
        int((lane.sessions[sid].codes()[half:] != expect[sid])
            .any(axis=-1).sum())
        for sid in range(n_streams))
    s = fleet.summary("cell")
    return {
        "streams": n_streams, "requests": s["requests"],
        "deploy_ok": bool(event.ok), "to_version": event.to_version,
        "state_migration": s["swap_history"][-1]["state_migration"],
        "dropped": s["requests"] - s["completed"], "wrong": wrong,
    }


def sweep(scales=(64, 256, 1024), t_steps: int = 8, block: int = 256,
          depth: int = 2, reps: int = 5, churn_events: int = 60,
          swap_streams: int = 32, seed: int = 0, full: bool = True) -> dict:
    task, cc, params, comp = _make_cell(seed, full)
    results = {
        "schema_version": SCHEMA_VERSION,
        "cell": {"task": task, "n_in": cc.n_in, "n_state": cc.n_state,
                 "n_out": cc.n_out, "layers": len(cc.net.layers)},
        "block": block, "depth": depth, "t_steps": t_steps,
        "full_size": full,
        "scaling": _scaling_sweep(comp, scales, t_steps, block, depth,
                                  reps, seed + 1),
        "churn": _churn_identity(comp, churn_events, block, depth,
                                 seed + 2),
        "hot_swap": {
            "carried": _hot_swap_carried(
                comp, swap_streams, max(t_steps, 4), block, depth,
                seed + 3),
            "requantized": _hot_swap_requantized(
                comp, params, swap_streams, max(t_steps, 4), block, depth,
                seed + 4),
        },
    }
    return results


def contract_violations(results: dict) -> list:
    """The streaming serving contract, shared with check_regression."""
    bad = []
    for p in results["scaling"]:
        if not p["bit_identical"]:
            bad.append(f"scale {p['streams']}: streamed codes not "
                       "bit-identical to the offline scan")
    for be, r in results["churn"]["per_backend"].items():
        if not r["bit_identical"]:
            bad.append(f"churn[{be}]: streamed codes not bit-identical")
        if r["dropped"]:
            bad.append(f"churn[{be}]: {r['dropped']} steps dropped")
    for mode, hs in results["hot_swap"].items():
        if not hs["deploy_ok"]:
            bad.append(f"hot_swap[{mode}]: deploy did not land")
        if hs["state_migration"] != mode:
            bad.append(f"hot_swap[{mode}]: migration recorded as "
                       f"{hs['state_migration']!r}")
        if hs["dropped"]:
            bad.append(f"hot_swap[{mode}]: {hs['dropped']} steps dropped")
        if hs["wrong"]:
            bad.append(f"hot_swap[{mode}]: {hs['wrong']} wrong answers")
    if results["full_size"]:
        peak = max(p["streams"] for p in results["scaling"])
        if peak < 1000:
            bad.append(f"full sweep peaked at {peak} concurrent streams "
                       "(< 1000)")
    return bad


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smoke-sized sweep (CI perf-gate)")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()

    results = sweep(**(FAST_KW if args.fast else {}))
    out = write_results(results, args.out)

    print("streams,steps_per_s,p50_step_us,p99_step_us,blocks,state_bytes")
    for p in results["scaling"]:
        print(f"{p['streams']},{p['steps_per_s']},{p['p50_step_us']},"
              f"{p['p99_step_us']},{p['blocks']},{p['state_bytes']}")
    ch = results["churn"]
    for be, r in ch["per_backend"].items():
        print(f"churn[{be}] streams={ch['streams']} steps={ch['steps']} "
              f"bit_identical={r['bit_identical']} dropped={r['dropped']}")
    for mode, hs in results["hot_swap"].items():
        print(f"hot_swap[{mode}] migration={hs['state_migration']} "
              f"dropped={hs['dropped']} wrong={hs['wrong']} "
              f"requests={hs['requests']}")

    bad = contract_violations(results)
    if bad:
        raise SystemExit("stream serving contract violated:\n  "
                         + "\n  ".join(bad))
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
