"""Loop-aware analysis of optimized HLO text.

``compiled.cost_analysis()`` counts a while-loop body ONCE regardless of
trip count (verified empirically — see EXPERIMENTS.md §Roofline note), which
under-counts scanned-layer models by ~L x.  This module re-derives per-device
totals by walking the computation graph:

  * computations are parsed from the HLO text;
  * ``while`` ops multiply their body/condition totals by the trip count
    recovered from the loop condition's comparison constant;
  * ``fusion``/``call``/to_apply are followed at multiplicity 1;
  * per-op costs: dot FLOPs from operand shapes + dimension numbers,
    collective payload bytes from output shapes (with wire factors applied
    by the roofline layer).

This is deliberately a *structural* analyzer — it reads only shapes and
dimension numbers, no execution.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "f16": 2, "bf16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVE_KINDS = ("all-reduce", "all-gather", "reduce-scatter",
                    "all-to-all", "collective-permute")


def _parse_shape(s: str) -> Tuple[Optional[str], List[int]]:
    m = re.match(r"(\w+)\[([\d,]*)\]", s)
    if not m:
        return None, []
    dims = [int(d) for d in m.group(2).split(",") if d]
    return m.group(1), dims


def _shape_bytes(s: str) -> int:
    total = 0
    for m in re.finditer(r"(\w+)\[([\d,]*)\]", s):
        dt = m.group(1)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in m.group(2).split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Totals:
    flops: float = 0.0
    collective_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVE_KINDS})
    collective_counts: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVE_KINDS})

    def add(self, other: "Totals", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        for k in COLLECTIVE_KINDS:
            self.collective_bytes[k] += other.collective_bytes[k] * mult
            self.collective_counts[k] += other.collective_counts[k] * mult


@dataclasses.dataclass
class Computation:
    name: str
    lines: List[str]
    is_entry: bool = False


def split_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    current: Optional[Computation] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        m = re.match(r"(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{",
                     stripped)
        if m and not line.startswith(" " * 2):
            current = Computation(name=m.group(2), lines=[],
                                  is_entry=bool(m.group(1)))
            comps[current.name] = current
            continue
        if stripped == "}":
            current = None
            continue
        if current is not None:
            current.lines.append(stripped)
    return comps


_DEF_RE = re.compile(r"^(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\(?[\w\[\],\s\{\}]*?)\s+[\w\-]+\(")
_DOT_RE = re.compile(
    r"^(?:ROOT\s+)?%?[\w\.\-]+\s*=\s*(\w+)\[([\d,]*)\]\S*\s+dot\(([^)]*)\)")
# first dot operand: optionally an inlined type, then the instruction name
_DOT_LHS_RE = re.compile(r"dot\((?:(\w+)\[([\d,]*)\]\S*\s+)?%?([\w\.\-]+)")


def shape_table(comp: "Computation") -> Dict[str, str]:
    """instruction name -> result type string (first pass per computation)."""
    table: Dict[str, str] = {}
    for line in comp.lines:
        m = _DEF_RE.match(line)
        if m:
            table[m.group(1)] = m.group(2)
    return table


def dot_flops(line: str, table: Dict[str, str]) -> float:
    """FLOPs of one dot op: 2 * prod(output dims) * contracted size.

    The lhs shape is read from the inlined operand type when present
    (newer XLA text) and from the computation's symbol table otherwise
    (older optimized CPU HLO)."""
    m = _DOT_RE.match(line)
    if not m:
        return 0.0
    out_elems = 1
    for d in m.group(2).split(","):
        if d:
            out_elems *= int(d)
    lhs_dims: List[int] = []
    lm = _DOT_LHS_RE.search(line)
    if lm:
        if lm.group(1):  # inlined "f32[128,128]{1,0} %name" operand type
            lhs_dims = [int(d) for d in lm.group(2).split(",") if d]
        else:
            lhs_type = table.get(lm.group(3), "")
            _, lhs_dims = _parse_shape(lhs_type.replace("(", ""))
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
    contracted = 1
    if cm and lhs_dims:
        for idx in cm.group(1).split(","):
            if idx:
                contracted *= lhs_dims[int(idx)]
    return 2.0 * out_elems * contracted


_CALL_RE = re.compile(
    r"(?:calls=|to_apply=|body=|condition=)%?([\w\.\-]+)")
_WHILE_RE = re.compile(
    r"while\(.*?\), condition=%?([\w\.\-]+), body=%?([\w\.\-]+)")
_COLL_RE = re.compile(
    r"%?[\w\.\-]+ = (.*?)\s+(all-reduce|all-gather|reduce-scatter|"
    r"all-to-all|collective-permute)(-start)?\(")


def trip_count(cond: Computation) -> float:
    """Loop bound from the condition's comparison constant (scan pattern:
    compare(iter, constant(N), LT))."""
    consts = []
    for line in cond.lines:
        for m in re.finditer(r"constant\((\d+)\)", line):
            consts.append(int(m.group(1)))
    return float(max(consts)) if consts else 1.0


def analyze(hlo: str) -> Totals:
    comps = split_computations(hlo)
    memo: Dict[str, Totals] = {}

    def walk(name: str, depth: int = 0) -> Totals:
        if name in memo:
            return memo[name]
        comp = comps.get(name)
        total = Totals()
        if comp is None or depth > 40:
            memo[name] = total
            return total
        memo[name] = total  # break cycles
        table = shape_table(comp)
        for line in comp.lines:
            total.flops += dot_flops(line, table)
            cm = _COLL_RE.match(line)
            if cm and "-done" not in line.split("=", 1)[1][:48]:
                kind = cm.group(2)
                total.collective_bytes[kind] += _shape_bytes(cm.group(1))
                total.collective_counts[kind] += 1
            wm = _WHILE_RE.search(line)
            if wm:
                cond_name, body_name = wm.group(1), wm.group(2)
                trips = trip_count(comps[cond_name]) \
                    if cond_name in comps else 1.0
                body_tot = walk(body_name, depth + 1)
                total.add(body_tot, mult=max(trips, 1.0))
                continue
            # fusions / calls / reducers at multiplicity 1
            for cname in _CALL_RE.findall(line):
                if cname in comps and "while(" not in line:
                    total.add(walk(cname, depth + 1))
        return total

    entry = next((c.name for c in comps.values() if c.is_entry), None)
    if entry is None:
        return Totals()
    memo.pop(entry, None)
    return walk(entry)


def analyze_compiled(compiled) -> Totals:
    return analyze(compiled.as_text())
